"""Negative tests for the trace re-verifier (docs/failures.md).

``replay_verify_sim_report`` is the auditor of record for every sim/gateway
trace, including failure/migration traces.  These tests corrupt an otherwise
valid trace one field at a time — drop a departure, inflate a demand, reorder
timestamps, tamper a migration audit entry — and assert the verifier rejects
it *with an actionable message naming the violation*, not just ``False``.
Each tamper targets one specific check, so a refactor that silently weakens
a check shows up here as a passing replay of a corrupt trace.
"""
from __future__ import annotations

import math
from dataclasses import replace

import pytest

from repro.core import IF, nsfnet, resnet101_profile
from repro.serve import (FailureEvent, ServeSim, ServedRequest,
                         generate_fleet, plan_footprint, replay_verify_sim,
                         replay_verify_sim_report)

NET = nsfnet()
PROF = resnet101_profile()


def _fleet(n, seed=0, **kw):
    return generate_fleet(NET, n, "v4", "v13", 2, IF, 3, seed=seed,
                          arrival="poisson", hold_model="exp",
                          hold_time_s=6.0, **kw)


def _copy(served):
    """Round-trip through the serialized form: what a reloaded artifact sees
    (and a fresh mutable copy safe to corrupt)."""
    return [ServedRequest.from_dict(s.to_dict()) for s in served]


def _failure_run():
    """A deterministic run with at least one completed migration: fail a
    link under a live chain's footprint mid-hold, recover it later."""
    fleet = _fleet(14, seed=2)
    base = ServeSim(NET, PROF, retry=True).run(fleet)
    victim = next(s for s in base.served
                  if s.accepted and s.depart_s is not None
                  and s.depart_s - s.admit_s > 1.0
                  and plan_footprint(s.plan)[0])
    link = sorted(plan_footprint(victim.plan)[0])[0]
    t_fail = victim.admit_s + 0.25 * (victim.depart_s - victim.admit_s)
    failures = [FailureEvent(t_s=t_fail, kind="link_down", link=link),
                FailureEvent(t_s=t_fail + 3.0, kind="recover", link=link)]
    out = ServeSim(NET, PROF, retry=True).run(fleet, failures=failures)
    assert any(s.migrations for s in out.served), \
        "fixture must produce at least one migration"
    assert replay_verify_sim(NET, PROF, out.served, failures=out.failures)
    return out


OUT = _failure_run()


def _tamperable():
    served = _copy(OUT.served)
    idx = next(i for i, s in enumerate(served) if s.migrations)
    return served, served[idx]


# ------------------------------------------------------------ record tampers
def test_baseline_trace_verifies():
    assert replay_verify_sim_report(
        NET, PROF, _copy(OUT.served), failures=OUT.failures) is None


def test_accepted_record_without_plan_is_rejected():
    served, rec = _tamperable()
    rec.plan = None
    msg = replay_verify_sim_report(NET, PROF, served, failures=OUT.failures)
    assert msg is not None and "has no plan" in msg
    assert f"request_id={rec.request.request_id}" in msg


def test_inflated_demand_exceeds_residual_capacity():
    served, rec = _tamperable()
    rec.request = replace(rec.request, rate_rps=1e9)  # absurd bandwidth need
    msg = replay_verify_sim_report(NET, PROF, served, failures=OUT.failures)
    assert msg is not None and "exceeds residual capacity" in msg


def test_reordered_admit_depart_tie_is_rejected():
    """Swapping a chain's admit/depart instants makes its release precede
    its commit — the replay must call out the uncommitted release."""
    served = _copy(OUT.served)
    rec = next(s for s in served if s.accepted and s.depart_s is not None
               and not s.migrations and s.failed_s is None)
    rec.admit_s, rec.depart_s = rec.depart_s, rec.admit_s
    msg = replay_verify_sim_report(NET, PROF, served, failures=OUT.failures)
    assert msg is not None and "never committed" in msg


def test_dropped_departure_leaks_capacity():
    """Erasing a departure leaves its demand committed forever; the replay
    must detect the leak the moment any later commit no longer fits."""
    fleet = _fleet(32, seed=0)  # overloaded: retries wait on departures
    sim = ServeSim(NET, PROF, retry=True).run(fleet)
    assert replay_verify_sim(NET, PROF, sim.served)
    retried = [s for s in sim.served if s.accepted and s.n_retries > 0]
    assert retried, "fixture must exercise the retry queue"
    served = _copy(sim.served)
    # drop every departure that freed capacity before the first retry admit
    t_retry = min(s.admit_s for s in retried)
    for s in served:
        if s.accepted and s.depart_s is not None and s.depart_s <= t_retry:
            s.depart_s = None
    msg = replay_verify_sim_report(NET, PROF, served)
    assert msg is not None and "exceeds residual capacity" in msg


# --------------------------------------------------------- migration tampers
def test_migration_timestamps_out_of_order():
    served, rec = _tamperable()
    m = rec.migrations[0]
    m["t_restored"] = m["t_down"] - 1.0
    msg = replay_verify_sim_report(NET, PROF, served, failures=OUT.failures)
    assert msg is not None and "timestamps out of order" in msg


def test_migration_moved_bytes_mismatch():
    served, rec = _tamperable()
    rec.migrations[0]["moved_bytes"] += 12345.0
    msg = replay_verify_sim_report(NET, PROF, served, failures=OUT.failures)
    assert msg is not None and "moved_bytes mismatch" in msg


def test_migration_disruption_shorter_than_outage():
    served, rec = _tamperable()
    m = rec.migrations[0]
    # disruption must cover at least the outage interval; under-reporting it
    # (e.g. to flatter the cost model) is a trace corruption
    m["disruption_s"] = (m["t_restored"] - m["t_down"]) - 1.0
    msg = replay_verify_sim_report(NET, PROF, served, failures=OUT.failures)
    assert msg is not None and "shorter than its outage" in msg


def test_migration_missing_old_plan_is_malformed():
    served, rec = _tamperable()
    del rec.migrations[0]["old_plan"]
    msg = replay_verify_sim_report(NET, PROF, served, failures=OUT.failures)
    assert msg is not None and "malformed migration entries" in msg


def test_failed_before_last_restoration_is_rejected():
    served, rec = _tamperable()
    rec.failed_s = rec.migrations[-1]["t_restored"] - 1.0
    msg = replay_verify_sim_report(NET, PROF, served, failures=OUT.failures)
    assert msg is not None and "precedes its last restoration" in msg


def test_unmigrated_chain_spanning_down_resource_is_rejected():
    """Erasing a victim's migration history claims it sat on the failed
    link through the outage — down_ok must veto the instant of the mark."""
    served, rec = _tamperable()
    old_plan = rec.migrations[0]["old_plan"]  # flat plan dict
    rec.plan = ServedRequest.from_dict(
        {**rec.to_dict(), **old_plan, "migrations": []}).plan
    rec.migrations = []
    rec.failed_s = None
    msg = replay_verify_sim_report(NET, PROF, served, failures=OUT.failures)
    assert msg is not None and "down resource" in msg


def test_bool_and_report_forms_agree():
    served, rec = _tamperable()
    rec.migrations[0]["moved_bytes"] *= 2.0
    assert not replay_verify_sim(NET, PROF, served, failures=OUT.failures)
    assert replay_verify_sim_report(
        NET, PROF, served, failures=OUT.failures) is not None
