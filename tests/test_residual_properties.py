"""Property suite for ResidualState under commit/release/fail/recover
interleavings (docs/failures.md).

The invariants locked down here are what every serve driver trusts blindly:

* ``conservation_ok`` after *every* operation — the running tallies, the
  base-capacity bounds, and the resource->chains reverse index all re-derive
  from the committed list at any interleaving point;
* a fully drained state has exactly-zero tallies (no float residue survives
  the exact-count snap in ``release``) and empty indexes;
* releasing a chain that is not committed — double release, or a chain that
  was never admitted — raises ``KeyError`` instead of silently corrupting
  the accounting;
* ``fail_link`` / ``fail_node`` return exactly the committed chains whose
  footprint touches the resource, in commit order, and committing onto a
  down resource raises.

A deterministic seeded grid always runs; the same machine is additionally
fuzzed with >= 200 random interleavings when ``hypothesis`` is installed
(optional — without it the grid is the coverage, not a skip of the module).
"""
from __future__ import annotations

import random
from dataclasses import replace

import pytest

from repro.core import IF, TR, candidate_sets, nsfnet, resnet101_profile, solve
from repro.serve import ResidualState, ServeRequest, plan_footprint

NET = nsfnet()
PROF = resnet101_profile()
NODES = sorted(NET.nodes)
LINKS = sorted(NET.links)


def _make_pool():
    """A few solved (request, plan) shapes to commit copies of: distinct
    batch sizes, modes, and candidate seeds give distinct footprints."""
    pool = []
    for seed, (b, mode) in enumerate([(1, IF), (2, IF), (4, IF), (2, TR)]):
        cands = candidate_sets(3, seed, NODES, "v4", "v13", 2)
        req = ServeRequest(
            request_id=0, source="v4", destination="v13", batch_size=b,
            mode=mode, K=3, candidates=tuple(tuple(c) for c in cands),
            rate_rps=1.0)
        out = solve(req.problem(NET, PROF), "bcd")
        if out.plan is not None:
            pool.append((req, out.plan))
    assert len(pool) >= 3, "pool construction should find feasible plans"
    return pool


POOL = _make_pool()


def _assert_drained_exactly(state: ResidualState) -> None:
    """Every tally is exactly zero (fits() may have seeded defaultdict keys,
    so emptiness means all-zero values, not no keys) and the indexes are
    empty."""
    for tally in (state.used_link_fw, state.used_link_bw,
                  state.used_mem, state.used_disk):
        assert all(v == 0.0 for v in tally.values()), dict(tally)
    assert not state.committed
    assert not state._hosted_links
    assert not state._hosted_nodes
    assert not state._commit_seq
    assert state.conservation_ok(PROF)


def run_interleaving(rng: random.Random, n_ops: int = 60) -> None:
    """One randomized commit/release/fail/recover schedule, with the full
    invariant battery asserted after every operation."""
    state = ResidualState(NET)
    committed: dict[int, tuple[ServeRequest, object]] = {}
    uid = 0
    for _ in range(n_ops):
        op = rng.choice(("commit", "commit", "release", "fail_link",
                         "fail_node", "recover"))
        if op == "commit":
            req0, plan = POOL[rng.randrange(len(POOL))]
            req = replace(req0, request_id=uid)
            if state.fits(PROF, req, plan):
                state.commit(PROF, req, plan)
                committed[uid] = (req, plan)
                uid += 1
            else:
                # a plan that does not fit (or touches a down resource)
                # must be rejected by commit too, with nothing mutated
                if not state.footprint_clear(plan):
                    with pytest.raises(ValueError):
                        state.commit(PROF, req, plan)
        elif op == "release" and committed:
            rid = rng.choice(sorted(committed))
            req, plan = committed.pop(rid)
            state.release(PROF, req, plan)
        elif op == "fail_link":
            u, v = LINKS[rng.randrange(len(LINKS))]
            victims = state.fail_link(u, v)
            # exactly the committed chains whose footprint crosses the link,
            # in commit order (uid assignment is commit order)
            want = sorted(
                rid for rid, (_, plan) in committed.items()
                if {(u, v), (v, u)} & plan_footprint(plan)[0])
            assert victims == want
            for rid in victims:  # the migration engine releases every victim
                req, plan = committed.pop(rid)
                state.release(PROF, req, plan)
            assert state.down_ok()
        elif op == "fail_node":
            node = NODES[rng.randrange(len(NODES))]
            victims = state.fail_node(node)
            want = sorted(
                rid for rid, (_, plan) in committed.items()
                if node in plan_footprint(plan)[1]
                or any(node in link for link in plan_footprint(plan)[0]))
            assert victims == want
            for rid in victims:
                req, plan = committed.pop(rid)
                state.release(PROF, req, plan)
            assert state.down_ok()
        elif op == "recover":
            if state.down_nodes and rng.random() < 0.5:
                state.recover_node(rng.choice(sorted(state.down_nodes)))
            elif state.down_links:
                u, v = rng.choice(sorted(state.down_links))
                state.recover_link(u, v)
        assert state.conservation_ok(PROF), f"conservation broken after {op}"
    # drain everything still committed: the state must compare clean
    for rid in sorted(committed):
        req, plan = committed.pop(rid)
        state.release(PROF, req, plan)
    _assert_drained_exactly(state)


# ------------------------------------------------------- deterministic grid
@pytest.mark.parametrize("seed", range(12))
def test_random_interleaving_grid(seed):
    run_interleaving(random.Random(seed * 9176 + 3))


def test_double_release_raises():
    req0, plan = POOL[0]
    req = replace(req0, request_id=7)
    state = ResidualState(NET)
    state.commit(PROF, req, plan)
    state.release(PROF, req, plan)
    with pytest.raises(KeyError):
        state.release(PROF, req, plan)
    _assert_drained_exactly(state)


def test_release_of_never_committed_raises():
    req0, plan = POOL[0]
    state = ResidualState(NET)
    with pytest.raises(KeyError):
        state.release(PROF, replace(req0, request_id=1), plan)
    # a second chain's commit must not make a foreign release acceptable
    other_req, other_plan = POOL[1]
    state.commit(PROF, replace(other_req, request_id=2), other_plan)
    with pytest.raises(KeyError):
        state.release(PROF, replace(req0, request_id=1), plan)
    assert state.conservation_ok(PROF)


def test_commit_onto_down_resource_raises():
    req0, plan = POOL[0]
    req = replace(req0, request_id=11)
    state = ResidualState(NET)
    links, nodes = plan_footprint(plan)
    u, v = sorted(links)[0]
    state.fail_link(u, v)
    with pytest.raises(ValueError, match="down resource"):
        state.commit(PROF, req, plan)
    state.recover_link(u, v)
    state.commit(PROF, req, plan)  # recovery restores commitability
    node = sorted(nodes)[0]
    state.release(PROF, req, plan)
    state.fail_node(node)
    with pytest.raises(ValueError, match="down resource"):
        state.commit(PROF, req, plan)
    assert state.conservation_ok(PROF)


def test_exact_zero_after_many_cycles():
    """Hundreds of commit/release cycles on hot keys must drain to exactly
    zero — the count-based snap, not an epsilon, decides emptiness."""
    state = ResidualState(NET)
    for i in range(300):
        req0, plan = POOL[i % len(POOL)]
        req = replace(req0, request_id=i)
        if state.fits(PROF, req, plan):
            state.commit(PROF, req, plan)
            state.release(PROF, req, plan)
    _assert_drained_exactly(state)


# ------------------------------------------------------ hypothesis fuzzing
try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:  # optional dependency; deterministic grid still ran
    _HAS_HYPOTHESIS = False

if _HAS_HYPOTHESIS:

    @settings(max_examples=200, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1),
           n_ops=st.integers(10, 80))
    def test_random_interleaving_fuzz(seed, n_ops):
        run_interleaving(random.Random(seed), n_ops=n_ops)
