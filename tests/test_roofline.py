"""Trip-count-aware HLO cost analysis: closed-form toys (the A0 meta-iteration
of EXPERIMENTS.md §Perf) + collective parsing."""
import subprocess
import sys
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow  # subprocess dry-runs compile whole models

SRC = str(Path(__file__).resolve().parents[1] / "src")

TOY = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.roofline.hlo_cost import analyze_hlo

mesh = jax.make_mesh((4,), ("data",))

def step(w, x):
    def body(h, wi):
        return jnp.tanh(h @ wi), None
    h, _ = jax.lax.scan(body, x, w)
    return h.sum()

ws = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32, sharding=NamedSharding(mesh, P()))
xs = jax.ShapeDtypeStruct((8, 64), jnp.float32, sharding=NamedSharding(mesh, P()))
mc = analyze_hlo(jax.jit(step).lower(ws, xs).compile().as_text(), 4)
expected = 7 * 2 * 8 * 64 * 64
assert mc.unknown_trip_counts == 0, mc.unknown_trip_counts
assert expected <= mc.flops <= expected * 1.05, (mc.flops, expected)

# sharded variant: per-device flops + per-iteration all-gather bytes (the
# constraint inside the loop keeps the weight gather un-hoistable)
ws2 = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32, sharding=NamedSharding(mesh, P(None, "data")))
def step2(w, x):
    def body(h, wi):
        h = jax.lax.with_sharding_constraint(h, NamedSharding(mesh, P("data")))
        return jnp.tanh(h @ wi), None
    h, _ = jax.lax.scan(body, x, w)
    return h.sum()
mc2 = analyze_hlo(jax.jit(step2).lower(ws2, xs).compile().as_text(), 4)
assert expected / 4 * 0.9 <= mc2.flops <= expected * 1.3, mc2.flops
# collectives inside the loop body must be multiplied by the trip count
total_coll = mc2.total_coll_bytes
per_iter = 0.75 * 64 * 64 * 4  # ring (n-1)/n x one weight slice
assert total_coll >= 5 * per_iter, (total_coll, per_iter)
print("TOY OK")
"""


def test_hlo_cost_toys():
    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", TOY], capture_output=True,
                          text=True, timeout=300, env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "TOY OK" in proc.stdout


def test_collective_volume_formulas():
    from repro.roofline.analysis import collective_stats

    hlo = """
ENTRY %main () -> f32[] {
  %ar = f32[1024]{0} all-reduce(%x), replica_groups={{0,1,2,3}}
  %ag = f32[4096]{0} all-gather(%y), replica_groups=[1,4]<=[4], dimensions={0}
  %rs = f32[256]{0} reduce-scatter(%z), replica_groups={{0,1,2,3}}
  %cp = f32[512]{0} collective-permute(%w), source_target_pairs={{0,1}}
}
"""
    st = collective_stats(hlo, 4)
    assert st["counts"] == {"all-reduce": 1, "all-gather": 1,
                            "reduce-scatter": 1, "all-to-all": 0,
                            "collective-permute": 1}
    assert st["bytes_per_device"]["all-reduce"] == pytest.approx(
        2 * 0.75 * 1024 * 4)
    assert st["bytes_per_device"]["all-gather"] == pytest.approx(
        0.75 * 4096 * 4)
    assert st["bytes_per_device"]["reduce-scatter"] == pytest.approx(
        0.75 * 256 * 4 * 4)
    assert st["bytes_per_device"]["collective-permute"] == pytest.approx(512 * 4)


def test_roofline_terms_and_bottleneck():
    from repro.roofline.analysis import Roofline

    r = Roofline(arch="a", shape="s", mesh="m", chips=256,
                 flops_per_device=197e12,  # exactly 1 s of compute
                 hbm_bytes_per_device=819e9 * 2,  # 2 s of memory
                 coll_bytes_per_device=50e9 * 0.5,  # 0.5 s of collectives
                 model_flops_global=197e12 * 256)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(2.0)
    assert r.t_collective == pytest.approx(0.5)
    assert r.bottleneck == "memory"
    # at the memory bound, achievable useful throughput is half of peak
    assert r.roofline_fraction == pytest.approx(0.5)
    assert r.useful_flops_ratio == pytest.approx(1.0)


def test_dryrun_single_cell_end_to_end(tmp_path):
    """Smallest real cell compiles + produces a sound artifact (slow-ish)."""
    import json
    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--cell", "mamba2-370m",
         "long_500k", "single"],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=str(Path(SRC).parent))
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    art = Path(SRC).parent / "artifacts" / "dryrun" / \
        "mamba2-370m__long_500k__single.json"
    j = json.loads(art.read_text())
    assert j["status"] == "ok"
    assert j["memory"]["fits_16gb"]
    assert j["roofline"]["bottleneck"] in ("compute", "memory", "collective")
    assert j["cost"]["flops_per_device"] > 0
