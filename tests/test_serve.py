"""Serve layer: residual-capacity conservation, admission-policy invariants,
vectorized min-plus relaxation equivalence, and the sweep integration."""
import random

import pytest

from repro.core import (IF, TR, EvalCache, PhysicalNetwork, PlanEvaluator,
                        bcd_solve, candidate_sets, nsfnet, random_network,
                        resnet101_profile)
from repro.core.dfts import _relax_stage, _relax_stage_scalar, dfts
from repro.serve import (POLICIES, ResidualState, ServePlanner, ServedRequest,
                         generate_fleet, plan_demand, replay_verify)
from repro.sweep import ScenarioSpec, run_scenario, verify_result

NET = nsfnet()
PROF = resnet101_profile()


def _fleet(n=12, mode=IF, b=2, seed=0, **kw):
    return generate_fleet(NET, n, "v4", "v13", b, mode, 3, seed=seed, **kw)


# ------------------------------------------------------- residual conservation
@pytest.mark.parametrize("solver", ["bcd", "exact"])
@pytest.mark.parametrize("mode,b", [(IF, 2), (TR, 8)])
def test_accepted_chains_never_oversubscribe(solver, mode, b):
    fleet = _fleet(16, mode=mode, b=b)
    outcome = ServePlanner(NET, PROF, solver=solver).admit(fleet, policy="fcfs")
    assert outcome.n_requests == 16
    assert 0 < outcome.n_accepted <= 16
    # replay from scratch: every accepted plan must fit the residuals at its
    # admission point, and total usage must stay within base capacity
    assert replay_verify(NET, PROF, outcome.served)


def test_residual_state_tracks_plan_demands():
    fleet = _fleet(4)
    outcome = ServePlanner(NET, PROF).admit(fleet)
    state = ResidualState(NET)
    for s in outcome.served:
        if s.accepted:
            state.commit(PROF, s.request, s.plan)
    assert state.conservation_ok(PROF)
    # tampering with the tallies must break conservation
    if state.used_mem:
        node = next(iter(state.used_mem))
        state.used_mem[node] += 1.0
        assert not state.conservation_ok(PROF)


def test_training_chain_reserves_backward_bandwidth():
    fleet = _fleet(1, mode=TR, b=8)
    r = fleet[0]
    res = bcd_solve(NET, PROF, r.chain_request(), r.K, r.candidate_lists())
    assert res.feasible
    d = plan_demand(PROF, r, res.plan)
    assert d.link_fw_bps and all(v > 0 for v in d.link_fw_bps.values())
    assert any(v > 0 for v in d.link_bw_bps.values())
    assert d.node_mem_bytes and d.node_disk_bytes


def test_materialize_reduces_capacity_and_drops_saturated_links():
    state = ResidualState(NET)
    state.used_mem["v7"] = NET.nodes["v7"].mem_capacity / 2
    state.used_link_fw[("v4", "v5")] = NET.links[("v4", "v5")].bw_fw  # saturate
    res = state.materialize(IF)
    assert res.nodes["v7"].mem_capacity == pytest.approx(
        NET.nodes["v7"].mem_capacity / 2)
    assert ("v4", "v5") not in res.links
    assert ("v5", "v4") in res.links
    # keep_saturated keeps the link (clamped) for latency evaluation
    assert ("v4", "v5") in state.materialize(keep_saturated=True).links


def test_replanning_recovers_blocked_requests():
    fleet = _fleet(16)
    accept_no_replan = ServePlanner(NET, PROF, solver="exact",
                                    replan=False).admit(fleet).n_accepted
    with_replan = ServePlanner(NET, PROF, solver="exact").admit(fleet)
    assert with_replan.n_accepted >= accept_no_replan
    assert with_replan.n_replanned > 0  # the contended fabric forces replans


# ------------------------------------------------------------ policy invariants
def test_policy_orders():
    fleet = _fleet(9, arrival="poisson", seed=3)
    est = {r.request_id: float(r.request_id % 4) for r in fleet}
    fc = POLICIES["fcfs"](fleet, est)
    assert [r.arrival_s for r in fc] == sorted(r.arrival_s for r in fleet)
    lg = POLICIES["latency-greedy"](fleet, est)
    keys = [est[r.request_id] for r in lg]
    assert keys == sorted(keys)
    bd = POLICIES["batch-desc"](fleet, est)
    batches = [r.batch_size for r in bd]
    assert batches == sorted(batches, reverse=True)
    # all policies are permutations of the same fleet
    ids = sorted(r.request_id for r in fleet)
    for order in (fc, lg, bd):
        assert sorted(r.request_id for r in order) == ids


def test_admission_respects_policy_order():
    fleet = _fleet(8)
    outcome = ServePlanner(NET, PROF).admit(fleet, policy="batch-desc")
    batches = [s.request.batch_size for s in outcome.served]
    assert batches == sorted(batches, reverse=True)


def test_latency_greedy_never_accepts_fewer_cheap_chains():
    """Shortest-job-first on a saturated fabric accepts at least as many
    chains as admitting the expensive ones first."""
    fleet = _fleet(16)
    planner = ServePlanner(NET, PROF, solver="exact")
    greedy = planner.admit(fleet, policy="latency-greedy")
    desc = planner.admit(fleet, policy="batch-desc")
    assert greedy.n_accepted >= desc.n_accepted


def test_unknown_policy_and_solver_rejected():
    with pytest.raises(ValueError):
        ServePlanner(NET, PROF, solver="magic")
    with pytest.raises(ValueError):
        ServePlanner(NET, PROF).admit(_fleet(1), policy="magic")


# ------------------------------------------- vectorized min-plus relaxation
def _random_relax_cases(seed, n_nodes=18):
    rng = random.Random(seed)
    net = random_network(n_nodes, p=0.3, seed=seed)
    nodes = sorted(net.nodes)
    srcs = rng.sample(nodes, rng.randint(1, 4))
    best = {s: rng.uniform(0.0, 0.05) for s in srcs}
    targets = rng.sample(nodes, rng.randint(1, n_nodes))
    fw = rng.uniform(1e3, 1e7)
    bw = rng.uniform(1e3, 1e7) if rng.random() < 0.5 else None
    return net, best, fw, bw, targets


@pytest.mark.parametrize("seed", range(8))
def test_vectorized_relax_matches_scalar_on_random_graphs(seed):
    net, best, fw, bw, targets = _random_relax_cases(seed)
    vec = _relax_stage(net, best, fw, bw, targets)
    ref = _relax_stage_scalar(net, best, fw, bw, targets)
    assert vec == ref  # bit-for-bit: identical dists AND identical argmin


def test_vectorized_relax_matches_scalar_on_nsfnet_grid():
    """The paper's NSFNET grid: every cut size of ResNet101 at b in {2, 128},
    IF and TR, relaxed from the seeded candidate sets."""
    from repro.core import BW, FW

    net = nsfnet()
    nodes = sorted(net.nodes)
    for K, seed in ((3, 0), (5, 1)):
        cands = candidate_sets(K, seed, nodes, "v4", "v13")
        for b in (2, 128):
            for cut in range(1, PROF.L, 5):
                fw = b * PROF.cut_bytes(cut, FW)
                for bw in (None, b * PROF.cut_bytes(cut, BW)):
                    best = {c: 0.01 * i for i, c in enumerate(cands[0])}
                    for stage in cands[1:]:
                        out_v = _relax_stage(net, best, fw, bw, stage)
                        out_s = _relax_stage_scalar(net, best, fw, bw, stage)
                        assert out_v == out_s
                        best = {t: d for t, (d, _) in out_v.items()}


def test_dfts_with_scalar_relax_matches(monkeypatch):
    import sys

    dfts_mod = sys.modules["repro.core.dfts"]
    spec_cands = candidate_sets(4, 2, sorted(NET.nodes), "v4", "v13")
    fleet = _fleet(1, mode=TR, b=128)
    r = fleet[0].chain_request()
    segs = [(1, 9), (10, 18), (19, 27), (28, PROF.L)]
    vec_plan = dfts(NET, PROF, r, segs, spec_cands)
    monkeypatch.setattr(dfts_mod, "_relax_stage", dfts_mod._relax_stage_scalar)
    ref_plan = dfts(NET, PROF, r, segs, spec_cands)
    assert vec_plan.placement == ref_plan.placement
    assert vec_plan.paths == ref_plan.paths
    ev = PlanEvaluator(NET, PROF, r)
    assert ev.latency_s(vec_plan) == ev.latency_s(ref_plan)


# ----------------------------------------------------- EvalCache batch/mode keys
def test_eval_cache_keys_are_batch_and_mode_dependent():
    """One shared cache across heterogeneous requests must not leak entries
    between batch sizes or modes (the serve layer relies on this)."""
    cache = EvalCache()
    fleet_small = _fleet(1, b=1)
    fleet_big = _fleet(1, b=128, mode=TR)
    ev_a = PlanEvaluator(NET, PROF, fleet_small[0].chain_request(), cache=cache)
    ev_b = PlanEvaluator(NET, PROF, fleet_big[0].chain_request(), cache=cache)
    ca = ev_a.segment_comp_s("v7", 1, 10)
    cb = ev_b.segment_comp_s("v7", 1, 10)
    assert ca != cb  # b=1/IF vs b=128/TR must not collide in the memo
    # private evaluators agree with the shared-cache values
    assert ca == PlanEvaluator(NET, PROF,
                               fleet_small[0].chain_request()).segment_comp_s(
                                   "v7", 1, 10)
    assert cb == PlanEvaluator(NET, PROF,
                               fleet_big[0].chain_request()).segment_comp_s(
                                   "v7", 1, 10)
    # fit queries from both requests land on distinct memo keys too
    ev_a.segment_fits("v13", 1, 10)
    ev_b.segment_fits("v13", 1, 10)
    assert len(cache.fits) == 2
    # key suffix: (batch, mode, schedule, n_microbatches)
    assert {k[3:] for k in cache.fits} == {(1, IF, "seq", 1), (128, TR, "seq", 1)}


def test_eval_cache_fork_fits_shares_comp_only():
    cache = EvalCache()
    fork = cache.fork_fits()
    assert fork.comp is cache.comp
    assert fork.fits is not cache.fits


# -------------------------------------------------- deterministic dijkstra ties
def _diamond(order):
    """Symmetric 4-node diamond with two equal-cost a->d paths; `order`
    permutes link insertion to emulate different dict orderings."""
    from repro.core import CPU_XEON_6226R, LinkSpec, NodeSpec

    net = PhysicalNetwork()
    for n in ("a", "b", "c", "d"):
        net.add_node(NodeSpec(n, CPU_XEON_6226R, 1e9, 1e9))
    links = [("a", "b"), ("a", "c"), ("b", "d"), ("c", "d")]
    for u, v in (links if order == 0 else links[::-1]):
        net.add_bidirectional(u, v, LinkSpec(1e9, 1e9, 1e-3, 1e-3))
    return net

def test_dijkstra_equal_cost_ties_are_deterministic():
    results = []
    for order in (0, 1):
        net = _diamond(order)
        dist, parent = net.dijkstra({"a": 0.0}, 1e6, None)
        _, path = net.shortest_path("a", "d", 1e6, None)
        results.append((dist, parent, path))
    assert results[0] == results[1]
    # the lexicographically smallest equal-cost parent wins
    assert results[0][1]["d"] == "b"
    assert results[0][2] == ["a", "b", "d"]


# ----------------------------------------------------------- sweep integration
def test_serve_scenario_spec_round_trip_and_run():
    spec = ScenarioSpec(
        topology="nsfnet", topology_kwargs={"source": "v4"},
        profile="resnet101", source="v4", destination="v13",
        batch_size=2, mode=IF, K=3, solver="bcd",
        n_requests=6, arrival="poisson", policy="latency-greedy",
        tags={"suite": "test"})
    clone = ScenarioSpec.from_dict(spec.to_dict())
    assert clone == spec and clone.spec_hash() == spec.spec_hash()
    # serve knobs are solve-relevant: they must change the content hash
    assert spec.spec_hash() != ScenarioSpec.from_dict(
        {**spec.to_dict(), "policy": "fcfs"}).spec_hash()
    assert spec.spec_hash() != ScenarioSpec.from_dict(
        {**spec.to_dict(), "n_requests": 12}).spec_hash()

    result = run_scenario(spec, use_context_cache=False)
    assert result.feasible
    assert result.acceptance_ratio == result.n_accepted / 6
    assert len(result.served) == 6
    assert result.latency_p50_s is not None
    assert result.latency_p50_s <= (result.latency_p95_s or 0.0) + 1e-12
    assert verify_result(result)
    # record round-trip through the JSON-able dicts
    served = [ServedRequest.from_dict(d) for d in result.served]
    assert [s.request.request_id for s in served] is not None


def test_serve_spec_validation():
    base = dict(topology="nsfnet", profile="resnet101", source="v4",
                destination="v13", batch_size=2, mode=IF, K=3)
    with pytest.raises(ValueError):
        ScenarioSpec(**base, n_requests=0)
    with pytest.raises(ValueError):
        ScenarioSpec(**base, arrival="burst")
    with pytest.raises(ValueError):
        ScenarioSpec(**base, policy="magic")


def test_multirequest_suite_smoke():
    from repro.sweep import SUITES, SweepRunner, comparison_report

    specs = SUITES["nsfnet_multirequest"](quick=True, schemes=("exact", "bcd"))
    results = SweepRunner(workers=0).run(specs)
    assert len(results) == len(specs)
    report = comparison_report(results)
    acc_exact = report["summary"]["exact"]["mean_acceptance_ratio"]
    acc_bcd = report["summary"]["bcd"]["mean_acceptance_ratio"]
    assert acc_exact is not None and acc_bcd is not None
    # the exact replanner can never admit fewer chains than the BCD heuristic
    # on these grids (it subsumes BCD's feasible set per replan)
    assert acc_exact >= acc_bcd - 1e-12
    for r in results:
        assert verify_result(r)


# ---------------------------------------------- cache observability (issue 7)
def test_eval_cache_counts_hits_and_misses():
    cache = EvalCache()
    assert cache.hits == 0 and cache.misses == 0
    assert cache.hit_rate is None  # no traffic yet
    r = _fleet(1)[0]
    ev = PlanEvaluator(NET, PROF, r.chain_request(), cache=cache)
    ev.segment_comp_s("v7", 1, 10)
    assert (cache.hits, cache.misses) == (0, 1)
    ev.segment_comp_s("v7", 1, 10)  # memoized
    assert (cache.hits, cache.misses) == (1, 1)
    ev.segment_fits("v13", 1, 10)
    ev.segment_fits("v13", 1, 10)
    assert (cache.hits, cache.misses) == (2, 2)
    s = cache.stats()
    assert s["hits"] == 2 and s["misses"] == 2
    assert s["hit_rate"] == pytest.approx(0.5)
    assert s["n_comp"] == 1 and s["n_fits"] == 1
    # a fork shares the comp table but counts its own traffic
    fork = cache.fork_fits()
    PlanEvaluator(NET, PROF, r.chain_request(),
                  cache=fork).segment_comp_s("v7", 1, 10)
    assert (fork.hits, fork.misses) == (1, 0)  # warm comp entry, fresh counters
    assert (cache.hits, cache.misses) == (2, 2)  # parent untouched


def test_solver_stats_surface_cache_counters():
    fleet = _fleet(8)
    planner = ServePlanner(NET, PROF)
    out = planner.admit(fleet)
    stats = out.solver_stats()
    ec = stats["cache"]["eval_cache"]
    assert ec["hits"] > 0 and ec["misses"] > 0
    assert 0.0 < ec["hit_rate"] < 1.0
    assert "plan_cache" not in stats["cache"]  # none attached by default
    # with a PlanCache attached, its hit rate flows through solver_stats too
    from repro.serve import PlanCache

    pc = PlanCache()
    warm = ServePlanner(NET, PROF, plan_cache=pc)
    first = warm.admit(fleet)
    assert first.solver_stats()["cache"]["plan_cache"]["misses"] > 0
    again = warm.admit(fleet)  # identical shapes: every presolve is a hit
    pstats = again.solver_stats()["cache"]["plan_cache"]
    assert pstats["hits"] >= len({r.solve_key(NET, PROF) for r in fleet})
    assert pstats["hit_rate"] > 0.0


# ------------------------------------------------- mixed training fleets (TR)
def test_generate_fleet_train_share_twin_stability():
    """A mixed fleet and its all-IF twin draw modes from a dedicated RNG
    stream: arrivals, batch sizes, rates, and candidate sets are identical
    request for request — only the mode flips (docs/training.md)."""
    kw = dict(seed=3, arrival="poisson", arrival_rate_rps=4.0)
    base = _fleet(16, **kw)
    mixed = _fleet(16, train_share=0.5, **kw)
    assert len(base) == len(mixed) == 16
    for a, b in zip(base, mixed):
        assert (a.arrival_s, a.batch_size, a.rate_rps, a.candidates) == \
            (b.arrival_s, b.batch_size, b.rate_rps, b.candidates)
    assert {r.mode for r in base} == {IF}
    modes = [r.mode for r in mixed]
    assert TR in modes and IF in modes  # 16 draws at p=.5: both present


def test_generate_fleet_train_share_monotone_and_extremes():
    def n_tr(share):
        return sum(r.mode == TR
                   for r in _fleet(32, seed=7, train_share=share))

    counts = [n_tr(s) for s in (0.0, 0.25, 0.5, 0.75, 1.0)]
    # same seed => same uniform draws => flips are monotone in the share
    assert counts == sorted(counts)
    assert counts[0] == 0 and counts[-1] == 32
    with pytest.raises(ValueError):
        _fleet(8, train_share=1.5)


def test_mode_split_reports_per_mode_contention():
    fleet = _fleet(10, seed=1, train_share=0.5, schedule="pipe",
                   n_microbatches=4)
    out = ServePlanner(NET, PROF).admit(fleet)
    split = out.mode_split()
    assert set(split) == {r.mode for r in fleet}
    assert sum(m["n_requests"] for m in split.values()) == 10
    assert sum(m["n_accepted"] for m in split.values()) == out.n_accepted
    for m, row in split.items():
        n = sum(r.mode == m for r in fleet)
        assert row["n_requests"] == n
        assert row["acceptance_ratio"] == pytest.approx(
            row["n_accepted"] / n)
        if row["n_accepted"]:
            assert row["latency_p50_s"] <= row["latency_p95_s"] + 1e-12
