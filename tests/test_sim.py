"""ServeSim: event-driven dynamic admission (docs/sim.md).

Anchoring invariants: release exactly inverts commit, a simulation with
infinite holding times reproduces the static admission round bit-for-bit,
conservation holds at every event of a churn trace, and churn strictly beats
the static round on overloaded fleets (departures free capacity).
"""
import math

import pytest

from repro.core import IF, TR, nsfnet, resnet101_profile
from repro.serve import (HOLD_MODELS, ResidualState, ServePlanner, ServeSim,
                        ServedRequest, generate_fleet, replay_verify_sim)
from repro.sweep import (SUITES, ScenarioSpec, SweepRunner, churn_pairs,
                        comparison_report, run_scenario, verify_result)

NET = nsfnet()
PROF = resnet101_profile()
INF = float("inf")


def _fleet(n=12, mode=IF, b=2, seed=0, **kw):
    return generate_fleet(NET, n, "v4", "v13", b, mode, 3, seed=seed, **kw)


def _static_fields(s: ServedRequest):
    """The static-round fields of a served record (sim adds admit/depart)."""
    return (s.request, s.accepted, s.replanned, s.latency_s, s.plan, s.reason,
            s.status)


# --------------------------------------------------------- release <-> commit
def test_release_exactly_inverts_commit():
    fleet = _fleet(6)
    outcome = ServePlanner(NET, PROF).admit(fleet)
    accepted = [s for s in outcome.served if s.accepted]
    assert len(accepted) >= 2
    state = ResidualState(NET)
    for s in accepted:
        state.commit(PROF, s.request, s.plan)
    assert state.conservation_ok(PROF)
    for s in accepted:
        state.release(PROF, s.request, s.plan)
    # a fully drained state is exactly empty — no float residue survives
    assert not state.committed
    assert not dict(state.used_link_fw) and not dict(state.used_link_bw)
    assert not dict(state.used_mem) and not dict(state.used_disk)
    assert state.conservation_ok(PROF)


def test_release_interleaved_keeps_conservation():
    fleet = _fleet(8)
    outcome = ServePlanner(NET, PROF).admit(fleet)
    accepted = [s for s in outcome.served if s.accepted]
    state = ResidualState(NET)
    for s in accepted:
        state.commit(PROF, s.request, s.plan)
    # release a middle chain (not LIFO) — conservation must re-derive cleanly
    victim = accepted[len(accepted) // 2]
    state.release(PROF, victim.request, victim.plan)
    assert state.conservation_ok(PROF)
    assert all(req != victim.request for req, _ in state.committed)


def test_release_of_uncommitted_chain_raises():
    fleet = _fleet(2)
    outcome = ServePlanner(NET, PROF).admit(fleet)
    s = next(r for r in outcome.served if r.accepted)
    state = ResidualState(NET)
    with pytest.raises(KeyError):
        state.release(PROF, s.request, s.plan)
    state.commit(PROF, s.request, s.plan)
    state.release(PROF, s.request, s.plan)
    with pytest.raises(KeyError):  # double release is a caller bug
        state.release(PROF, s.request, s.plan)


# -------------------------------------------- static equivalence (inf holds)
@pytest.mark.parametrize("policy", ["fcfs", "latency-greedy", "batch-desc"])
def test_sim_with_infinite_holds_matches_static_round(policy):
    """duration_s = inf means no departures: the event loop must reproduce
    today's ServePlanner.admit bit-for-bit (plans, latencies, order)."""
    fleet = _fleet(16)
    static = ServePlanner(NET, PROF).admit(fleet, policy=policy)
    sim = ServeSim(NET, PROF).run(fleet, policy=policy)
    assert [_static_fields(s) for s in sim.served] == \
           [_static_fields(s) for s in static.served]
    assert sim.n_presolved == static.n_presolved
    assert sim.status == static.status
    # no chain ever departs and nothing is retried
    assert sim.n_departed == 0 and sim.n_retried == 0
    assert all(s.depart_s is None for s in sim.served)
    assert replay_verify_sim(NET, PROF, sim.served)


def test_sim_poisson_fcfs_with_infinite_holds_matches_static():
    fleet = _fleet(12, arrival="poisson", seed=3)
    static = ServePlanner(NET, PROF).admit(fleet, policy="fcfs")
    sim = ServeSim(NET, PROF).run(fleet, policy="fcfs")
    assert [_static_fields(s) for s in sim.served] == \
           [_static_fields(s) for s in static.served]
    # admitted at their arrival instants
    for s in sim.served:
        if s.accepted:
            assert s.admit_s == s.request.arrival_s


# ------------------------------------------------------------- churn dynamics
def _churn_fleet(n=32, seed=0):
    return _fleet(n, seed=seed, arrival="poisson", hold_model="exp",
                  hold_time_s=4.0)


def test_churn_accepts_strictly_more_than_static_when_overloaded():
    fleet = _churn_fleet()
    static = ServePlanner(NET, PROF).admit(fleet)
    sim = ServeSim(NET, PROF, retry=True).run(fleet)
    assert static.n_accepted < len(fleet)  # the static round is overloaded
    assert sim.n_accepted > static.n_accepted
    assert sim.n_departed > 0
    assert replay_verify_sim(NET, PROF, sim.served)


def test_churn_trace_conserves_at_every_event():
    """Replay the trace event by event: every commit fits the residuals at
    its instant and conservation re-derives after each arrival/departure."""
    sim = ServeSim(NET, PROF, retry=True).run(_churn_fleet())
    assert replay_verify_sim(NET, PROF, sim.served)
    # tampering with one accepted chain's departure must break the replay
    # (its demand would be released while still accounted as committed)
    tampered = [ServedRequest.from_dict(s.to_dict()) for s in sim.served]
    victim = next(s for s in tampered if s.accepted and s.depart_s is not None)
    victim.depart_s = victim.admit_s - 1.0  # departs before it was admitted
    assert not replay_verify_sim(NET, PROF, tampered)


def test_retry_queue_admits_blocked_requests_on_departures():
    fleet = _churn_fleet()
    no_retry = ServeSim(NET, PROF, retry=False).run(fleet)
    retry = ServeSim(NET, PROF, retry=True).run(fleet)
    assert retry.n_accepted >= no_retry.n_accepted
    assert retry.n_retried > 0
    for s in retry.served:
        if s.accepted and s.n_retries > 0:
            assert s.admit_s > s.request.arrival_s  # waited in the queue
    assert retry.blocking_probability <= no_retry.blocking_probability


def test_sim_metrics_are_consistent():
    sim = ServeSim(NET, PROF, retry=True).run(_churn_fleet())
    curve = sim.concurrent_curve()
    assert all(n >= 0 for _, n in curve)
    assert max(n for _, n in curve) == sim.peak_concurrent
    assert [t for t, _ in curve] == sorted(t for t, _ in curve)
    acc = sim.acceptance_curve()
    assert all(0.0 <= a <= 1.0 for _, a in acc)
    assert acc[-1][1] == pytest.approx(sim.acceptance_ratio)
    assert 0.0 <= sim.blocking_probability <= 1.0
    epochs = sim.epoch_percentiles(n_epochs=4)
    assert len(epochs) == 4
    assert sum(e["n"] for e in epochs) == sim.n_accepted
    for e in epochs:
        if e["n"]:
            assert e["p50"] <= e["p95"] <= e["p99"]
    s = sim.sim_summary()
    assert s["peak_concurrent"] == sim.peak_concurrent
    assert s["n_departed"] == sim.n_departed


def test_served_request_sim_fields_round_trip():
    sim = ServeSim(NET, PROF, retry=True).run(_churn_fleet(n=8))
    for s in sim.served:
        back = ServedRequest.from_dict(s.to_dict())
        assert back == s
        assert back.request.duration_s == s.request.duration_s


# ------------------------------------------------------------ fleet holding
def test_generate_fleet_hold_models():
    base = _fleet(8, arrival="poisson")
    assert all(r.duration_s == INF for r in base)
    fixed = _fleet(8, arrival="poisson", hold_model="fixed", hold_time_s=2.5)
    assert all(r.duration_s == 2.5 for r in fixed)
    exp = _fleet(8, arrival="poisson", hold_model="exp", hold_time_s=2.5)
    assert all(0 < r.duration_s < INF for r in exp)
    assert len({r.duration_s for r in exp}) > 1  # actually random
    # dedicated hold stream: arrivals/candidates identical across hold models
    for a, b, c in zip(base, fixed, exp):
        assert a.arrival_s == b.arrival_s == c.arrival_s
        assert a.candidates == b.candidates == c.candidates
    # seeded determinism
    again = _fleet(8, arrival="poisson", hold_model="exp", hold_time_s=2.5)
    assert [r.duration_s for r in again] == [r.duration_s for r in exp]
    with pytest.raises(ValueError):
        _fleet(4, hold_model="gamma")
    with pytest.raises(ValueError):
        _fleet(4, hold_model="fixed")  # needs a finite hold_time_s


# ------------------------------------------------------------ sweep integration
def test_sim_scenario_spec_knobs_and_validation():
    spec = ScenarioSpec(
        topology="nsfnet", topology_kwargs={"source": "v4"},
        profile="resnet101", source="v4", destination="v13",
        batch_size=2, mode=IF, K=3, solver="bcd",
        n_requests=8, arrival="poisson", policy="fcfs",
        sim=True, hold_model="exp", duration_s=4.0, retry=True)
    clone = ScenarioSpec.from_dict(spec.to_dict())
    assert clone == spec and clone.spec_hash() == spec.spec_hash()
    # churn knobs are solve-relevant: they must change the content hash
    for patch in ({"sim": False, "hold_model": "none", "duration_s": None,
                   "retry": False},
                  {"duration_s": 8.0}, {"retry": False},
                  {"hold_model": "fixed"}):
        other = ScenarioSpec.from_dict({**spec.to_dict(), **patch})
        assert other.spec_hash() != spec.spec_hash()
        # ... but all pair on churn_key with the static counterpart
        assert other.churn_key() == spec.churn_key()
    base = dict(topology="nsfnet", profile="resnet101", source="v4",
                destination="v13", batch_size=2, mode=IF, K=3, n_requests=8)
    with pytest.raises(ValueError):  # holds without the sim
        ScenarioSpec(**base, hold_model="exp", duration_s=4.0)
    with pytest.raises(ValueError):  # retry without the sim
        ScenarioSpec(**base, retry=True)
    with pytest.raises(ValueError):  # exp holds need a duration
        ScenarioSpec(**base, sim=True, hold_model="exp")
    with pytest.raises(ValueError):  # duration without a hold model
        ScenarioSpec(**base, sim=True, duration_s=4.0)
    with pytest.raises(ValueError):  # sim needs a fleet
        ScenarioSpec(**{**base, "n_requests": 1}, sim=True)


def test_sim_scenario_runs_and_verifies():
    spec = ScenarioSpec(
        topology="nsfnet", topology_kwargs={"source": "v4"},
        profile="resnet101", source="v4", destination="v13",
        batch_size=2, mode=IF, K=3, solver="bcd",
        n_requests=12, arrival="poisson", policy="fcfs",
        sim=True, hold_model="exp", duration_s=4.0, retry=True,
        tags={"suite": "test"})
    result = run_scenario(spec, use_context_cache=False)
    assert result.feasible
    assert result.status in ("optimal", "feasible")
    assert result.solver_stats["n_presolved"] >= 1
    assert result.blocking_probability is not None
    assert result.peak_concurrent >= 1
    assert result.sim["horizon_s"] > 0
    assert len(result.served) == 12
    assert verify_result(result)
    # corrupting the trace must fail verification
    bad = run_scenario(spec, use_context_cache=False)
    for d in bad.served:
        if d["accepted"] and d.get("depart_s") is not None:
            d["depart_s"] = d["admit_s"] - 1.0
            break
    assert not verify_result(bad)


def test_nsfnet_churn_suite_shows_uplift():
    """The acceptance criterion: under finite churn the suite admits strictly
    more than the static round on at least one overloaded cell, with the
    event traces replay-verified."""
    specs = SUITES["nsfnet_churn"](quick=True)
    assert any(s.sim for s in specs) and any(not s.sim for s in specs)
    results = SweepRunner(workers=0).run(specs)
    assert len(results) == len(specs)
    assert all(r.error is None for r in results)
    pairs = churn_pairs(results)
    assert pairs  # every sim cell found its static counterpart
    overloaded = [p for p in pairs.values() if p["static_acceptance"] < 1.0]
    assert overloaded
    assert any(p["churn_acceptance"] > p["static_acceptance"]
               for p in overloaded)
    report = comparison_report(results)
    assert report["churn_comparison"]["n_pairs"] == len(pairs)
    assert report["churn_comparison"]["mean_uplift"] > 0
    for r in results:
        assert verify_result(r)


# --------------------------------------------------- epoch bucketing edge cases
def test_epoch_percentiles_admit_exactly_at_t0():
    """admit_s == 0.0 is a legitimate t=0 admission, not a missing timestamp
    — it must bucket into epoch 0, while a record with admit_s=None (imported
    from a static round) falls back to its arrival instant."""
    import dataclasses

    fleet = _fleet(2)
    at_zero = ServedRequest(fleet[0], True, latency_s=1.0, admit_s=0.0,
                            depart_s=5.0)
    static_import = ServedRequest(
        dataclasses.replace(fleet[1], arrival_s=7.5), True, latency_s=2.0,
        admit_s=None)
    from repro.serve import SimOutcome

    sim = SimOutcome(policy="fcfs", solver="bcd",
                     served=[at_zero, static_import], horizon_s=10.0)
    epochs = sim.epoch_percentiles(n_epochs=4)
    assert [e["n"] for e in epochs] == [1, 0, 0, 1]
    assert epochs[0]["p50"] == pytest.approx(1.0)
    assert epochs[3]["p50"] == pytest.approx(2.0)
    assert sum(e["n"] for e in epochs) == sim.n_accepted


# ---------------------------------------------- simultaneous departure ordering
def test_simultaneous_departures_drain_before_retry():
    """A batch fleet with one fixed holding time departs in synchronized
    waves: every chain admitted at t=0 leaves at exactly T, so instant T has
    many simultaneous departures.  The retry queue must be re-attempted only
    after *all* of them drain — pinned by the wave invariant: a chain
    admitted at k*T failed exactly once per earlier wave (n_retries == k).
    Retrying between individual releases would re-attempt queued requests
    against a partially freed fabric and inflate their retry counts."""
    T = 2.0
    fleet = _fleet(16, hold_model="fixed", hold_time_s=T)
    sim = ServeSim(NET, PROF, retry=True).run(fleet)
    assert sim.n_retried > 0  # the fleet overloads the fabric at t=0
    waves = {}
    for s in sim.served:
        if s.accepted:
            k = round(s.admit_s / T)
            assert s.admit_s == pytest.approx(k * T)
            assert s.n_retries == k
            waves.setdefault(k, []).append(s.request.request_id)
    assert len(waves) >= 2  # at least one synchronized-departure retry wave
    # within a wave, the queue is drained in (arrival_s, request_id) order —
    # all arrivals are 0 here, so decision order is increasing request id
    for k, ids in waves.items():
        if k > 0:
            assert ids == sorted(ids)
    assert replay_verify_sim(NET, PROF, sim.served)
