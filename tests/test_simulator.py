"""Chain simulator: a planner Plan executes end-to-end with real sub-models and
matches the monolithic forward pass; planner latency decomposition is charged."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import TR, ServiceChainRequest, exact_solve, tpu_pod_topology
from repro.models import transformer as T
from repro.models.layers import Ctx
from repro.msl import group_profile
from repro.msl.simulator import ChainSimulator


@pytest.mark.parametrize("arch", ["qwen3-14b", "mamba2-370m"])
def test_chain_execution_matches_monolithic(arch):
    # deepen the reduced config so K=2 stages have >=1 group each
    cfg = ARCHS[arch].reduced(n_layers=4 * len(ARCHS[arch].pattern))
    R = cfg.n_layers // len(cfg.pattern)
    params = T.init_params(jax.random.PRNGKey(0), cfg)

    # plan directly on THIS model's group profile over the pod topology
    net = tpu_pod_topology(n_groups=4, chips_per_group=8)
    nodes = sorted(net.nodes)
    prof = group_profile(cfg, seq_len=16, mode="train")
    assert prof.L == R
    req = ServiceChainRequest(arch, nodes[0], nodes[-1], 2, TR)
    cands = [[nodes[0]], [nodes[-1]]]
    res = exact_solve(net, prof, req, 2, cands)
    assert res.feasible

    sim = ChainSimulator(cfg, params, net, prof, req)
    B, S = 2, 16
    tokens = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (B, S)), jnp.int32)
    out = sim.run_plan(res.plan, tokens)
    assert len(out.traces) == res.plan.K
    assert out.total_charged_s > 0

    # monolithic reference (pre-final-norm hidden states)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = T.embed_tokens(params, cfg, tokens)
    ref, _, _ = T.apply_stack(params["stack"], cfg, cfg.n_layers, cfg.pattern,
                              x, Ctx(mode="prefill", positions=pos), None)
    # bf16 residual accumulation: scan-fused vs python-unrolled orderings
    # round differently through 4 SSD/attn layers (abs scale here is O(10))
    err = float(jnp.max(jnp.abs(out.hidden.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    scale = float(jnp.max(jnp.abs(ref.astype(jnp.float32)))) + 1e-6
    assert err / scale < 2e-2, (err, scale)

    # every inter-stage hop charged transmission + propagation; measured
    # compute feeds the straggler calibrator's sample format
    for t in out.traces[:-1]:
        assert t.transfer_s_charged > 0
        assert t.smashed_bytes > 0
    for t in out.traces:
        assert t.compute_s_measured > 0 and t.compute_s_predicted > 0
