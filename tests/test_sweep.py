"""Sweep engine: spec round-trip, cache-hit equivalence, artifact reload, and
a quick-mode NSFNET suite smoke test."""
import json
import math
import random

import pytest

from repro.core import IF, TR, EvalCache, LayerProfile, ModelProfile
from repro.sweep import (
    SUITES,
    ScenarioSpec,
    SweepRunner,
    apply_faults,
    comparison_report,
    run_scenario,
    verify_result,
)
from repro.sweep.artifacts import load_artifact, write_artifacts
from repro.sweep.runner import clear_context
from repro.sweep.spec import build_topology


def _spec(**kw) -> ScenarioSpec:
    base = dict(topology="nsfnet", topology_kwargs={"source": "v4"},
                profile="resnet101", source="v4", destination="v13",
                batch_size=2, mode=IF, K=3, solver="bcd",
                candidates=[["v4"], ["v7", "v11"], ["v13"]],
                tags={"suite": "test"})
    base.update(kw)
    return ScenarioSpec(**base)


# ------------------------------------------------------------------ spec schema
def test_spec_dict_round_trip():
    spec = _spec(drop_links=[("v4", "v5")], solver_kwargs={})
    d = spec.to_dict()
    json.loads(json.dumps(d))  # JSON-able
    clone = ScenarioSpec.from_dict(d)
    assert clone == spec
    assert clone.key() == spec.key()
    assert clone.spec_hash() == spec.spec_hash()


def test_spec_hash_ignores_labels_but_not_solve_fields():
    a, b = _spec(), _spec(name="renamed", tags={"x": "1"})
    assert a.spec_hash() == b.spec_hash()
    assert a.group_key() == _spec(solver="exact").group_key()
    assert a.spec_hash() != _spec(batch_size=4).spec_hash()


def test_spec_validation():
    with pytest.raises(ValueError):
        _spec(mode="XX")
    with pytest.raises(ValueError):
        _spec(solver="magic")
    with pytest.raises(KeyError):
        ScenarioSpec(topology="nope").build_network()


def test_fault_injection_removes_nodes_and_links():
    net = build_topology("nsfnet", {"source": "v4"})
    faulted = apply_faults(net, drop_nodes=["v7"], drop_links=[("v4", "v5")])
    assert "v7" not in faulted.nodes
    assert all("v7" not in e for e in faulted.links)
    assert ("v4", "v5") not in faulted.links
    assert ("v5", "v4") not in faulted.links
    assert ("v4", "v2") in faulted.links  # the rest of the fabric survives


# ------------------------------------------------------- cache-hit equivalence
def test_profile_prefix_sums_match_naive():
    rng = random.Random(0)
    layers = [LayerProfile(f"l{i}", rng.uniform(1e6, 1e9), rng.uniform(1e6, 1e9),
                           rng.uniform(1e3, 1e6), rng.uniform(1e3, 1e6),
                           rng.uniform(1e3, 1e8), rng.uniform(1e3, 1e8))
              for i in range(12)]
    prof = ModelProfile("rand", layers)
    for lo in range(1, 13):
        for hi in range(lo, 13):
            assert math.isclose(prof.seg_flops(lo, hi, "FW"),
                                sum(l.flops_fw for l in layers[lo - 1:hi]),
                                rel_tol=1e-12)
            assert math.isclose(prof.seg_mem_bytes(lo, hi),
                                sum(l.mem_bytes for l in layers[lo - 1:hi]),
                                rel_tol=1e-12)


@pytest.mark.parametrize("solver", ["exact", "bcd", "comp-ms", "comm-ms"])
def test_cached_vs_uncached_identical(solver):
    spec = _spec(solver=solver, mode=TR, batch_size=128)
    cold = run_scenario(spec, use_context_cache=False)
    clear_context()
    warm1 = run_scenario(spec)  # populates the shared context caches
    warm2 = run_scenario(spec)  # served from warm EvalCache + frontier caches
    for warm in (warm1, warm2):
        assert warm.feasible == cold.feasible
        assert warm.latency_s == pytest.approx(cold.latency_s, rel=1e-12)
        assert warm.segments == cold.segments
        assert warm.placement == cold.placement
        assert warm.paths == cold.paths


def test_eval_cache_shared_across_seeds_matches_private():
    shared = EvalCache()
    spec_a = _spec(candidates=None, candidate_seed=0)
    spec_b = _spec(candidates=None, candidate_seed=1)
    net, prof = spec_a.build_network(), spec_a.build_profile()
    from repro.core import bcd_solve

    lat_private = [
        bcd_solve(net, prof, s.request(), s.K, s.build_candidates(net)).latency_s
        for s in (spec_a, spec_b)
    ]
    lat_shared = [
        bcd_solve(net, prof, s.request(), s.K, s.build_candidates(net),
                  cache=shared).latency_s
        for s in (spec_a, spec_b)
    ]
    assert lat_shared == pytest.approx(lat_private, rel=1e-12)
    assert shared.comp  # the shared tables were actually used


# -------------------------------------------------- artifacts + disk cache
def test_run_artifact_reload_round_trip(tmp_path):
    specs = [_spec(solver=s) for s in ("exact", "bcd")]
    results = SweepRunner(workers=0).run(specs)
    paths = write_artifacts(tmp_path, "unit", results, meta={"quick": True})
    meta, reloaded = load_artifact(paths["json"])
    assert meta["suite"] == "unit" and meta["meta"]["quick"] is True
    assert len(reloaded) == len(results)
    for orig, back in zip(results, reloaded):
        assert back.spec == orig.spec
        assert back.latency_s == orig.latency_s
        # reconstruct the plan from the artifact and re-evaluate it
        assert verify_result(back)
    assert paths["csv"].read_text().count("\n") == len(results) + 1


def test_runner_without_context_cache_matches():
    specs = [_spec(solver="bcd"), _spec(solver="exact")]
    warm = SweepRunner(workers=0).run(specs)
    cold = SweepRunner(workers=0, use_context_cache=False).run(specs)
    for w, c in zip(warm, cold):
        assert c.latency_s == pytest.approx(w.latency_s, rel=1e-12)
        assert c.segments == w.segments and c.placement == w.placement


def test_workers_mapping_is_explicit():
    """0/1 -> serial in-process, n>=2 -> n processes, None/negative -> all
    cores (the documented contract of SweepRunner/the --workers flag)."""
    import os

    cpus = os.cpu_count() or 1
    assert SweepRunner.resolve_workers(0) == 0
    assert SweepRunner.resolve_workers(1) == 1
    assert SweepRunner.resolve_workers(4) == 4
    assert SweepRunner.resolve_workers(None) == cpus
    assert SweepRunner.resolve_workers(-1) == cpus
    assert SweepRunner(workers=0).workers == 0  # the default stays serial
    assert SweepRunner(workers=None).workers == cpus


def test_disk_cache_serves_second_run(tmp_path):
    specs = [_spec(solver=s) for s in ("exact", "bcd", "comm-ms")]
    runner = SweepRunner(cache_dir=tmp_path / "cache", workers=0)
    cold = runner.run(specs)
    assert runner.last_stats["n_solved"] == 3
    warm = runner.run(specs)
    assert runner.last_stats["n_cache_hits"] == 3
    assert runner.last_stats["n_solved"] == 0
    for c, w in zip(cold, warm):
        assert w.from_cache and not c.from_cache
        assert w.latency_s == c.latency_s
        assert w.segments == c.segments


# ---------------------------------------------------------- error robustness
def _crashing_spec():
    """K=9 on 14-node NSFNET: candidate_sets needs 14 intermediates but only
    12 exist — raises at fleet/candidate construction inside the scenario."""
    return ScenarioSpec(topology="nsfnet", topology_kwargs={"source": "v4"},
                        profile="resnet101", source="v4", destination="v13",
                        batch_size=2, mode=IF, K=9, solver="bcd",
                        tags={"suite": "test"})


@pytest.mark.parametrize("workers", [0, 2])
def test_one_crashing_scenario_does_not_lose_the_sweep(workers, tmp_path):
    specs = [_spec(solver="exact"), _crashing_spec(), _spec(solver="bcd")]
    runner = SweepRunner(cache_dir=tmp_path / "cache", workers=workers)
    results = runner.run(specs)
    assert len(results) == 3
    assert results[0].feasible and results[2].feasible
    bad = results[1]
    assert not bad.feasible and bad.status == "error"
    assert "candidate_sets" in bad.error and "K=9" in bad.error
    assert runner.last_stats["n_errors"] == 1
    assert runner.last_stats["n_solved"] == 2
    assert bad.spec.scenario_id() in runner.last_stats["errors"]
    assert not verify_result(bad)  # a crashed scenario is never verifiable
    # completed results were stored; the errored one is retried next run
    warm = runner.run(specs)
    assert runner.last_stats["n_cache_hits"] == 2
    assert runner.last_stats["n_errors"] == 1
    assert warm[0].from_cache and warm[2].from_cache


def test_error_results_survive_artifacts_and_report(tmp_path):
    results = SweepRunner(workers=0).run([_spec(solver="bcd"), _crashing_spec()])
    report = comparison_report(results)
    assert report["summary"]["bcd"]["n_errors"] == 1
    paths = write_artifacts(tmp_path, "unit_err", results)
    _, reloaded = load_artifact(paths["json"])
    assert reloaded[1].status == "error" and "candidate_sets" in reloaded[1].error
    assert "error" in paths["csv"].read_text().splitlines()[0]


# ------------------------------------------------- serve status threading
def test_serve_scenario_populates_status_and_solver_stats():
    """Regression: serve rows used to report status=None despite the engine
    dispatch — the planner's solve outcomes must reach the artifact."""
    spec = ScenarioSpec(topology="nsfnet", topology_kwargs={"source": "v4"},
                        profile="resnet101", source="v4", destination="v13",
                        batch_size=2, mode=IF, K=3, solver="exact",
                        n_requests=4, policy="fcfs", tags={"suite": "test"})
    result = run_scenario(spec, use_context_cache=False)
    assert result.feasible
    assert result.status == "optimal"  # every accepted solve was the exact DP
    stats = result.solver_stats
    assert stats["n_presolved"] >= 1
    assert sum(stats["statuses"].values()) == 4
    bcd = run_scenario(ScenarioSpec.from_dict(
        {**spec.to_dict(), "solver": "bcd"}), use_context_cache=False)
    assert bcd.status == "feasible"  # heuristic solves are never optimal


# ----------------------------------------------------------------- suite smoke
def test_nsfnet_paper_quick_suite_smoke():
    specs = SUITES["nsfnet_paper"](quick=True, modes=(IF,), schemes=("exact", "bcd"))
    results = SweepRunner(workers=0).run(specs)
    assert len(results) == len(specs)
    assert all(r.feasible for r in results)
    report = comparison_report(results)
    # the exact DP is the optimality reference: BCD can never beat it
    assert report["summary"]["bcd"]["mean_gap_pct"] >= -1e-6
    assert report["summary"]["exact"]["max_gap_pct"] == pytest.approx(0.0, abs=1e-9)
    for r in results:
        assert verify_result(r)


def test_all_suites_build():
    for name, fn in SUITES.items():
        specs = fn(quick=True)
        assert specs, name
        for s in specs:
            assert ScenarioSpec.from_dict(s.to_dict()) == s


# ------------------------------------------------ mixed training fleets (v8)
def test_train_share_validation_and_twin_key():
    serve = dict(n_requests=6, arrival="poisson", policy="fcfs")
    with pytest.raises(ValueError):
        _spec(train_share=1.5, **serve)
    with pytest.raises(ValueError):
        _spec(train_share=0.5)  # single-chain scenario has no fleet to mix
    mixed = _spec(train_share=0.5, **serve)
    twin = _spec(train_share=0.0, **serve)
    # training_key pairs a mixed fleet with its all-IF twin and nothing else
    assert mixed.training_key() == twin.training_key()
    assert mixed.spec_hash() != twin.spec_hash()
    assert mixed.training_key() != _spec(
        train_share=0.5, n_requests=8, arrival="poisson",
        policy="fcfs").training_key()
    clone = ScenarioSpec.from_dict(mixed.to_dict())
    assert clone == mixed and clone.train_share == 0.5


def test_mixed_training_suite_pairs_every_cell_with_if_twin():
    from repro.sweep import SUITES

    specs = SUITES["nsfnet_mixed_training"](quick=True)
    assert specs
    by_key: dict[str, set[float]] = {}
    for s in specs:
        assert s.schedule == "pipe" and s.n_microbatches > 1
        by_key.setdefault(s.training_key(), set()).add(s.train_share)
    for shares in by_key.values():
        assert 0.0 in shares and len(shares) > 1  # every cell has its twin


def test_training_contention_report_and_csv_columns(tmp_path):
    from repro.sweep import SweepRunner, comparison_report
    from repro.sweep.report import training_rows

    serve = dict(n_requests=6, arrival="poisson", policy="fcfs",
                 schedule="pipe", n_microbatches=4, candidate_seed=1,
                 candidates=None)
    specs = [_spec(train_share=s, name=f"mix{s}", **serve)
             for s in (0.0, 0.5)]
    results = SweepRunner(workers=0).run(specs)
    mixed = next(r for r in results if r.spec.train_share == 0.5)
    assert mixed.mode_split and set(mixed.mode_split) <= {"IF", "TR"}
    rows = training_rows(results)
    assert len(rows) == 1 and rows[0]["train_share"] == 0.5
    assert rows[0]["all_if_acceptance"] is not None  # twin was paired
    report = comparison_report(results)
    tc = report["training_contention"]
    assert tc["n_scenarios"] == 1
    assert (tc["n_train_requests"] + tc["n_inference_requests"]) == 6
    # artifacts: per-mode columns land in the CSV, JSON reloads bit-equal
    paths = write_artifacts(tmp_path, "mix", results)
    header = paths["csv"].read_text().splitlines()[0].split(",")
    for col in ("train_share", "tr_acceptance_ratio", "if_acceptance_ratio",
                "tr_latency_p95_s", "if_latency_p95_s"):
        assert col in header
    _, loaded = load_artifact(paths["json"])
    reloaded = next(r for r in loaded if r.spec.train_share == 0.5)
    assert reloaded.mode_split == mixed.mode_split
