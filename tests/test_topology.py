"""Topology regressions: ring-edge normalization in `random_network` and the
actionable `candidate_sets` capacity error."""
import random

import pytest

from repro.core import candidate_sets, nsfnet, random_network
from repro.core.topology import NSFNET_EDGES_KM


# ------------------------------------------------- random_network ring dedup
def test_random_network_never_duplicates_the_wraparound_edge():
    """p=1.0 draws every (i, j) pair, including (0, n-1) — which the ring
    used to store as (n-1, 0), double-adding the undirected link {v1, vN}
    and shifting the seeded delay stream.  Post-fix the edge set is exactly
    the distinct sorted pairs and each delay comes from one draw."""
    n = 6
    net = random_network(n, p=1.0, seed=3)
    assert len(net.links) == n * (n - 1)  # every pair, both directions, once
    # reconstruct the expected seeded stream: n*(n-1)/2 membership draws,
    # then one delay draw per *distinct* undirected edge in sorted order
    rng = random.Random(3)
    for _ in range(n * (n - 1) // 2):
        rng.random()
    for i in range(n):
        for j in range(i + 1, n):
            d = rng.uniform(1.23e-3, 14.2e-3)
            assert net.links[(f"v{i + 1}", f"v{j + 1}")].delay_fw == d
            assert net.links[(f"v{j + 1}", f"v{i + 1}")].delay_fw == d


@pytest.mark.parametrize("n,p,seed", [(2, 0.0, 0), (5, 0.3, 1), (12, 0.2, 7),
                                      (30, 0.2, 7)])
def test_random_network_edges_are_symmetric_deterministic_and_connected(n, p, seed):
    net = random_network(n, p=p, seed=seed)
    undirected = {frozenset(e) for e in net.links}
    assert len(net.links) == 2 * len(undirected)  # every link paired, no dup
    for (u, v), spec in net.links.items():
        assert net.links[(v, u)].delay_fw == spec.delay_fw
    # the connectivity ring survives normalization (incl. the wraparound)
    for i in range(1, n + 1):
        j = i % n + 1
        assert (f"v{i}", f"v{j}") in net.links
    again = random_network(n, p=p, seed=seed)
    assert {k: s.delay_fw for k, s in net.links.items()} == \
           {k: s.delay_fw for k, s in again.links.items()}


def test_nsfnet_edge_count_unchanged():
    net = nsfnet()
    assert len(net.links) == 2 * len(NSFNET_EDGES_KM)


# ------------------------------------------------ candidate_sets capacity error
def test_candidate_sets_raises_actionable_error_when_oversubscribed():
    nodes = [f"v{i}" for i in range(1, 15)]  # NSFNET: 12 intermediates
    with pytest.raises(ValueError) as ei:
        candidate_sets(9, 0, nodes, "v4", "v13", per_stage=2)
    msg = str(ei.value)
    assert "K=9" in msg and "per_stage=2" in msg and "12" in msg
    with pytest.raises(ValueError):
        candidate_sets(4, 0, ["v1", "v2", "v3"], "v1", "v3", per_stage=2)


def test_candidate_sets_boundary_still_works():
    nodes = [f"v{i}" for i in range(1, 15)]
    # exactly exhausts the 12 intermediates: per_stage * (K-2) == 12
    cands = candidate_sets(8, 0, nodes, "v4", "v13", per_stage=2)
    assert len(cands) == 8
    mids = [n for stage in cands[1:-1] for n in stage]
    assert len(mids) == 12 and len(set(mids)) == 12
    assert cands[0] == ["v4"] and cands[-1] == ["v13"]
    assert candidate_sets(2, 0, nodes, "v4", "v13") == [["v4"], ["v13"]]
