"""Round-trip training pipelines (docs/training.md).

Locks down the ``mode=TR, schedule=pipe, M > 1`` round-trip model end to end:

* closed form vs classic GPipe schedule length on a uniform chain;
* closed form vs an independent discrete-event F-then-B replay
  (``msl.simulator.executed_round_trip_s``) to 1e-9 relative;
* pipe-TR never slower than seq-TR (same plan and solver-vs-solver);
* scalar/JAX TR-pipe bit parity;
* EvalCache key disjointness across directions and (mode, schedule, M),
  including ``fork_fits`` shared-comp semantics, and PlanCache ``solve_key``
  disjointness;
* pinned regression anchors: seq+TR and every IF path is bit-for-bit the
  pre-round-trip evaluator (the dispatch must never reroute them).
"""
import random

import pytest

from repro.core import (
    BW,
    FW,
    IF,
    PIPE,
    TR,
    ComputeModel,
    EvalCache,
    LayerProfile,
    LinkSpec,
    ModelProfile,
    NodeSpec,
    PhysicalNetwork,
    Plan,
    PlanEvaluator,
    ProblemInstance,
    ServiceChainRequest,
    nsfnet,
    resnet101_profile,
    solve,
)
from repro.core.trainpipe import (
    evaluate_round_trip,
    round_trip_bottleneck_s,
    round_trip_stage_times,
    round_trip_taus,
    segment_comp_dir_s,
)
from repro.msl.simulator import executed_round_trip_s
from repro.sweep.spec import candidate_sets
from repro.sweep.suites import DEST, NSFNET_NODES, SOURCE

GB = 1024**3

NET = nsfnet(source=SOURCE)
PROF = resnet101_profile()


def _nsfnet_problem(mode=TR, K=3, b=128, seed=0, schedule=PIPE, M=4,
                    per_stage=2) -> ProblemInstance:
    cands = candidate_sets(K, seed, NSFNET_NODES, SOURCE, DEST,
                           per_stage=per_stage)
    req = ServiceChainRequest(
        model_id=PROF.model_id, source=SOURCE, destination=DEST,
        batch_size=b, mode=mode, schedule=schedule, n_microbatches=M)
    return ProblemInstance(NET, PROF, req, K, tuple(tuple(c) for c in cands))


def _random_instance(seed: int, n_nodes: int = 6, L: int = 6, K: int = 3,
                     schedule: str = PIPE, M: int = 4):
    """Random TR instance (same family as test_core_solvers, forced TR)."""
    rng = random.Random(seed)
    net = PhysicalNetwork()
    names = [f"n{i}" for i in range(n_nodes)]
    for i, name in enumerate(names):
        cm = ComputeModel(
            name=f"dev{i}",
            pieces=((float("inf"), rng.uniform(1e-12, 2e-10), 1e-12),),
            alpha_tau=rng.choice([0.0, 2e-13]), beta_tau=0.0)
        cap = rng.uniform(0.4, 4.0) * GB
        net.add_node(NodeSpec(name, cm, cap, cap))
    edges = {(i, (i + 1) % n_nodes) for i in range(n_nodes)}
    for i in range(n_nodes):
        for j in range(i + 1, n_nodes):
            if rng.random() < 0.4:
                edges.add((i, j))
    for i, j in edges:
        d = rng.uniform(1e-3, 15e-3)
        bw = rng.choice([0.5e9, 1e9, 2e9])
        net.add_bidirectional(names[i], names[j], LinkSpec(bw, bw, d, d))
    layers = []
    for l in range(L):
        fw = rng.uniform(0.1, 8.0) * 1e9
        act = rng.uniform(0.01, 3.0) * 1e6
        mem = rng.uniform(1, 300) * 1e6
        layers.append(LayerProfile(f"l{l}", fw, 2 * fw, act, act, mem, mem))
    prof = ModelProfile("rand", layers)
    s, d = names[0], names[-1]
    mids = names[1:-1]
    cands = ([[s]] + [rng.sample(mids, k=min(2, len(mids)))
                      for _ in range(K - 2)] + [[d]])
    b = rng.choice([4, 32, 128])
    req = ServiceChainRequest("rand", s, d, b, TR, schedule=schedule,
                              n_microbatches=M)
    return net, prof, req, K, cands


# ------------------------------------------------------- uniform GPipe anchor
@pytest.mark.parametrize("K,M", [(3, 4), (4, 2), (5, 8)])
def test_uniform_chain_matches_gpipe_schedule_length(K, M):
    """A uniform K-stage chain with zero-cost links reproduces the classic
    GPipe F-then-B makespan (M + K - 1) * (f_mb + b_mb) with per-microbatch
    stage times f/M, b/M — i.e. (M + K - 1) * (f + b) / M."""
    net = PhysicalNetwork()
    cm = ComputeModel(name="dev", pieces=((float("inf"), 1e-11, 0.0),))
    names = [f"n{i}" for i in range(K)]
    for name in names:
        net.add_node(NodeSpec(name, cm, GB, GB))
    for u, v in zip(names, names[1:]):
        # zero propagation; act/grad bytes below are 0 so transmission is 0
        net.add_bidirectional(u, v, LinkSpec(1e9, 1e9, 0.0, 0.0))
    layers = [LayerProfile(f"l{i}", 1e9, 2e9, 0.0, 0.0, 1.0, 1.0)
              for i in range(K)]
    prof = ModelProfile("uniform", layers)
    req = ServiceChainRequest("uniform", names[0], names[-1], 8, TR,
                              schedule=PIPE, n_microbatches=M)
    ev = PlanEvaluator(net, prof, req)
    plan = Plan(segments=[(i + 1, i + 1) for i in range(K)],
                placement=list(names),
                paths=[[u, v] for u, v in zip(names, names[1:])],
                tail_path=[])
    f = segment_comp_dir_s(ev, names[0], 1, 1, FW)
    b = segment_comp_dir_s(ev, names[0], 1, 1, BW)
    assert f > 0 and b == 2 * f  # uniform stages, BW flops = 2x FW
    out = evaluate_round_trip(ev, plan, M)
    assert out.total_s == pytest.approx((M + K - 1) * (f + b) / M, rel=1e-12)
    # and the independent event replay agrees exactly on this chain
    assert executed_round_trip_s(ev, plan, M) == pytest.approx(
        out.total_s, rel=1e-12)


# ------------------------------------------- closed form == discrete-event sim
@pytest.mark.parametrize("M", [2, 4, 7])
def test_closed_form_matches_event_replay_nsfnet(M):
    """Acceptance anchor: trainpipe's closed form equals the independently
    coded discrete-event GPipe replay of the executed chain on an NSFNET
    scenario, to 1e-9 relative."""
    p = _nsfnet_problem(M=M)
    res = solve(p, "bcd", cache=EvalCache())
    assert res.feasible
    ev = PlanEvaluator(NET, PROF, p.request)
    closed = evaluate_round_trip(ev, res.plan, M).total_s
    assert closed == pytest.approx(res.latency_s, rel=1e-12)
    executed = executed_round_trip_s(ev, res.plan, M)
    assert executed == pytest.approx(closed, rel=1e-9)


@pytest.mark.parametrize("seed", range(6))
def test_closed_form_matches_event_replay_random(seed):
    net, prof, req, K, cands = _random_instance(seed)
    res = solve(ProblemInstance(net, prof, req, K,
                                tuple(tuple(c) for c in cands)),
                "exact", cache=EvalCache())
    if not res.feasible:
        return
    ev = PlanEvaluator(net, prof, req)
    M = req.microbatches()
    closed = evaluate_round_trip(ev, res.plan, M).total_s
    assert executed_round_trip_s(ev, res.plan, M) == pytest.approx(
        closed, rel=1e-9)


# ---------------------------------------------------------- pipe-TR <= seq-TR
@pytest.mark.parametrize("b,K,M", [(2, 3, 4), (128, 3, 4), (128, 3, 16),
                                   (32, 5, 4)])
def test_pipe_tr_never_slower_than_seq_tr_nsfnet(b, K, M):
    """Quick-tier acceptance bound: the pipelined training solve is <= the
    sequential training solve (M = 1 is the seq chain; more microbatches
    only overlap work).  BCD is the production solver of the sweep tiers;
    its seq-anchor makes the bound unconditional (docs/pipeline.md)."""
    seq = _nsfnet_problem(K=K, b=b, schedule="seq", M=1)
    pipe = _nsfnet_problem(K=K, b=b, schedule=PIPE, M=M)
    r_seq = solve(seq, "bcd", cache=EvalCache())
    r_pipe = solve(pipe, "bcd", cache=EvalCache())
    assert r_seq.feasible and r_pipe.feasible
    assert r_pipe.latency_s <= r_seq.latency_s + 1e-12


@pytest.mark.parametrize("seed", range(6))
def test_pipe_tr_never_slower_than_seq_tr_random(seed):
    net, prof, req, K, cands = _random_instance(seed)
    seq_req = ServiceChainRequest(req.model_id, req.source, req.destination,
                                  req.batch_size, TR)
    cand_t = tuple(tuple(c) for c in cands)
    r_seq = solve(ProblemInstance(net, prof, seq_req, K, cand_t),
                  "exact", cache=EvalCache())
    r_pipe = solve(ProblemInstance(net, prof, req, K, cand_t),
                   "exact", cache=EvalCache())
    assert r_seq.feasible == r_pipe.feasible
    if not r_seq.feasible:
        return
    assert r_pipe.latency_s <= r_seq.latency_s + 1e-12
    # same-plan dominance: evaluating the seq optimum under the round-trip
    # model can only shrink it (t/M fill + (M-1)/M two-bottleneck drain)
    ev = PlanEvaluator(net, prof, req)
    M = req.microbatches()
    rt = evaluate_round_trip(ev, r_seq.plan, M).total_s
    assert rt <= r_seq.latency_s + 1e-12


def test_round_trip_decomposition_identities():
    """tau_fw/tau_bw are the max per-direction stage times; the bottleneck
    period is their sum; the bubble term is (M-1)/M of it."""
    M = 4
    p = _nsfnet_problem(M=M)
    res = solve(p, "bcd", cache=EvalCache())
    ev = PlanEvaluator(NET, PROF, p.request)
    fw_times, bw_times = round_trip_stage_times(ev, res.plan)
    tau_fw, tau_bw = round_trip_taus(ev, res.plan)
    assert tau_fw == max(fw_times) and tau_bw == max(bw_times)
    assert round_trip_bottleneck_s(ev, res.plan) == tau_fw + tau_bw
    out = evaluate_round_trip(ev, res.plan, M)
    assert out.bubble_s == (M - 1) * (tau_fw + tau_bw) / M
    # fill = everything but the bubble; stage times enter at their 1/M share
    assert out.computation_s + out.transmission_s == pytest.approx(
        (sum(fw_times) + sum(bw_times)) / M, rel=1e-12)


# ------------------------------------------------------- scalar/JAX bit parity
@pytest.mark.parametrize("b,M,seed", [(2, 4, 0), (128, 4, 0), (128, 16, 1),
                                      (32, 2, 2)])
def test_tr_pipe_jax_parity_bitwise(b, M, seed):
    """JAX TR-pipe twins return bit-identical plans and breakdowns."""
    p = _nsfnet_problem(b=b, M=M, seed=seed)
    for np_solver, jax_solver in (("dfts_np", "dfts_jax"),
                                  ("bcd", "bcd_jax")):
        ref = solve(p, np_solver, cache=EvalCache())
        acc = solve(p, jax_solver, cache=EvalCache())
        assert ref.feasible == acc.feasible
        if not ref.feasible:
            continue
        assert acc.plan == ref.plan
        assert acc.latency_s == ref.latency_s
        assert acc.latency == ref.latency  # full LatencyBreakdown, bit-equal


# --------------------------------------------------------- cache disjointness
def test_evalcache_direction_keys_disjoint_from_fused():
    """Per-direction comp entries (8-tuples) never alias fused entries
    (7-tuples) inside one shared EvalCache, across every (mode, schedule, M)
    variant of the same (network, profile)."""
    cache = EvalCache()
    variants = [
        ServiceChainRequest(PROF.model_id, SOURCE, DEST, 32, IF),
        ServiceChainRequest(PROF.model_id, SOURCE, DEST, 32, TR),
        ServiceChainRequest(PROF.model_id, SOURCE, DEST, 32, IF,
                            schedule=PIPE, n_microbatches=4),
        ServiceChainRequest(PROF.model_id, SOURCE, DEST, 32, TR,
                            schedule=PIPE, n_microbatches=4),
        ServiceChainRequest(PROF.model_id, SOURCE, DEST, 32, TR,
                            schedule=PIPE, n_microbatches=8),
    ]
    fused, directional = {}, {}
    for req in variants:
        ev = PlanEvaluator(NET, PROF, req, cache=cache)
        fused[(req.mode, req.schedule, req.n_microbatches)] = \
            ev.segment_comp_s("v7", 1, 10)
        directional[(req.mode, req.schedule, req.n_microbatches)] = (
            segment_comp_dir_s(ev, "v7", 1, 10, FW),
            segment_comp_dir_s(ev, "v7", 1, 10, BW))
    lens = {len(k) for k in cache.comp}
    assert lens == {7, 8}
    # one entry per variant per shape — (mode, schedule, M) keys never collide
    assert len([k for k in cache.comp if len(k) == 7]) == len(variants)
    assert len([k for k in cache.comp if len(k) == 8]) == 2 * len(variants)
    # shared-cache values equal fresh-cache values (no cross-contamination)
    for req in variants:
        ev = PlanEvaluator(NET, PROF, req)  # private cache
        key = (req.mode, req.schedule, req.n_microbatches)
        assert fused[key] == ev.segment_comp_s("v7", 1, 10)
        assert directional[key] == (
            segment_comp_dir_s(ev, "v7", 1, 10, FW),
            segment_comp_dir_s(ev, "v7", 1, 10, BW))
    # TR fused = FW + BW flops through one Eq.17 call; per-direction entries
    # are real splits of it (device overhead tau is charged per pass)
    fw, bw = directional[(TR, PIPE, 4)]
    assert fw + bw >= fused[(TR, PIPE, 4)] - 1e-15


def test_evalcache_fork_fits_shares_comp_only():
    cache = EvalCache()
    req = ServiceChainRequest(PROF.model_id, SOURCE, DEST, 32, TR,
                              schedule=PIPE, n_microbatches=4)
    ev = PlanEvaluator(NET, PROF, req, cache=cache)
    segment_comp_dir_s(ev, "v7", 1, 10, FW)
    ev.segment_fits("v7", 1, 10)
    fork = cache.fork_fits()
    assert fork.comp is cache.comp  # per-direction entries travel with it
    assert fork.fits is not cache.fits and not fork.fits
    assert fork.hits == fork.misses == 0  # fork counts its own traffic
    # a hit through the fork finds the per-direction entry without recompute
    misses_before = fork.misses
    ev_fork = PlanEvaluator(NET, PROF, req, cache=fork)
    segment_comp_dir_s(ev_fork, "v7", 1, 10, FW)
    assert fork.hits == 1 and fork.misses == misses_before


def test_plancache_solve_keys_disjoint_across_mode_schedule_m():
    """ServeRequest.solve_key (the PlanCache key) separates every
    (mode, schedule, M) variant of an otherwise identical request."""
    from repro.serve.plancache import PlanCache
    from repro.serve.requests import ServeRequest

    cands = tuple(tuple(c) for c in candidate_sets(
        3, 0, NSFNET_NODES, SOURCE, DEST, per_stage=2))

    def req(mode, schedule, M):
        return ServeRequest(request_id=0, source=SOURCE, destination=DEST,
                            batch_size=32, mode=mode, K=3, candidates=cands,
                            schedule=schedule, n_microbatches=M)

    variants = [req(IF, "seq", 1), req(TR, "seq", 1), req(IF, PIPE, 4),
                req(TR, PIPE, 4), req(TR, PIPE, 8)]
    keys = [r.solve_key(NET, PROF) for r in variants]
    assert len(set(keys)) == len(keys)
    # pipe with M=1 *is* the seq problem — the canonical content key folds it
    assert req(TR, PIPE, 1).solve_key(NET, PROF) == keys[1]
    # a TR-pipe outcome cached under its key is invisible to every other shape
    pc = PlanCache()
    outcome = solve(variants[3].problem(NET, PROF), "bcd",
                    cache=EvalCache())
    pc.put(keys[3], outcome)
    assert pc.get(keys[3]) is outcome
    for k in (keys[0], keys[1], keys[2], keys[4]):
        assert pc.get(k) is None


# ------------------------------------------------- pinned regression anchors
# Solver optima on the frozen NSFNET + resnet101 cell (K=3, seed-0
# candidates).  seq and IF values are pinned bit-for-bit: the round-trip
# dispatch must never reroute them.  The TR-pipe value pins the round-trip
# model itself against silent drift (BCD hits the exact TR-pipe optimum on
# this cell; the exact pair scan is too slow for the tier-1 suite).
_ANCHORS = [
    (IF, "seq", 1, 2, "exact", 0.04873493287462196),
    (IF, "seq", 1, 128, "exact", 2.6041812386841823),
    (TR, "seq", 1, 2, "exact", 0.10346391025679992),
    (TR, "seq", 1, 128, "exact", 5.337803813709429),
    (IF, PIPE, 4, 32, "exact", 0.2819212978341422),
    (TR, PIPE, 4, 128, "bcd", 2.5889623007019544),
]


@pytest.mark.parametrize("mode,schedule,M,b,solver,pinned", _ANCHORS)
def test_pinned_anchors(mode, schedule, M, b, solver, pinned):
    p = _nsfnet_problem(mode=mode, K=3, b=b, seed=0, schedule=schedule, M=M)
    res = solve(p, solver, cache=EvalCache())
    assert res.feasible
    assert res.latency_s == pinned  # bit-for-bit, not approx


def test_non_round_trip_paths_never_touch_trainpipe(monkeypatch):
    """seq+TR, every IF shape, and TR-pipe with M=1 stay on the fused
    evaluators — poison evaluate_round_trip and make sure nobody calls it."""
    import repro.core.trainpipe as trainpipe_mod

    def _boom(*a, **k):
        raise AssertionError("fused path reached the round-trip evaluator")

    monkeypatch.setattr(trainpipe_mod, "evaluate_round_trip", _boom)
    fused_cells = [
        (IF, "seq", 1, 2), (TR, "seq", 1, 128),
        (IF, PIPE, 4, 32), (TR, PIPE, 1, 128),
    ]
    for mode, schedule, M, b in fused_cells:
        p = _nsfnet_problem(mode=mode, b=b, schedule=schedule, M=M)
        res = solve(p, "exact", cache=EvalCache())
        assert res.feasible
    # and the poisoned module IS what the dispatch would call for TR-pipe M>1
    p = _nsfnet_problem(mode=TR, b=128, schedule=PIPE, M=4)
    ev = PlanEvaluator(NET, PROF, p.request)
    plan = Plan(segments=[(1, 12), (13, 24), (25, 37)],
                placement=[SOURCE, "v11", DEST],
                paths=[NET.shortest_path(SOURCE, "v11", 0.0, None)[1],
                       NET.shortest_path("v11", DEST, 0.0, None)[1]],
                tail_path=[])
    with pytest.raises(AssertionError, match="round-trip"):
        ev.evaluate(plan)


def test_exact_leq_every_bruteforce_round_trip_plan():
    """The TR-pipe exact optimum lower-bounds an exhaustive enumeration of
    (segmentation, placement) plans with shortest-hop subpaths."""
    import itertools

    net, prof, req, K, cands = _random_instance(1, n_nodes=5, L=5, K=3)
    res = solve(ProblemInstance(net, prof, req, K,
                                tuple(tuple(c) for c in cands)),
                "exact", cache=EvalCache())
    ev = PlanEvaluator(net, prof, req)
    M = req.microbatches()
    best = float("inf")
    L = prof.L
    for cuts in itertools.combinations(range(1, L), K - 1):
        segs, lo = [], 1
        for c in list(cuts) + [L]:
            segs.append((lo, c))
            lo = c + 1
        for placement in itertools.product(*cands):
            if not all(ev.segment_fits(n, lo_, hi_)
                       for (lo_, hi_), n in zip(segs, placement)):
                continue
            try:
                paths = []
                b = req.batch_size
                for k in range(K - 1):
                    fw = b * prof.cut_bytes(segs[k][1], FW)
                    bw = b * prof.cut_bytes(segs[k][1], BW)
                    _, path = net.shortest_path(placement[k],
                                                placement[k + 1], fw, bw)
                    paths.append(path)
                _, tail = net.shortest_path(placement[-1], req.destination,
                                            0.0, 0.0)
            except ValueError:
                continue
            plan = Plan(segments=segs, placement=list(placement),
                        paths=paths, tail_path=tail)
            best = min(best, evaluate_round_trip(ev, plan, M).total_s)
    if best == float("inf"):
        assert not res.feasible
    else:
        assert res.feasible
        assert res.latency_s <= best + 1e-12


# ------------------------------------------------------ serve-layer TR clamp
def test_effective_rate_clamped_by_round_trip_period():
    from repro.serve.requests import ServeRequest
    from repro.serve.residual import effective_rate_rps

    cands = tuple(tuple(c) for c in candidate_sets(
        3, 0, NSFNET_NODES, SOURCE, DEST, per_stage=2))
    p = _nsfnet_problem(mode=TR, b=128, M=4)
    res = solve(p, "bcd", cache=EvalCache())
    ev = PlanEvaluator(NET, PROF, p.request)
    period = round_trip_bottleneck_s(ev, res.plan)
    assert period > 0

    def serve_req(rate, mode=TR, schedule=PIPE, M=4):
        return ServeRequest(request_id=0, source=SOURCE, destination=DEST,
                            batch_size=128, mode=mode, K=3, candidates=cands,
                            rate_rps=rate, model_id=PROF.model_id,
                            schedule=schedule, n_microbatches=M)

    # above the sustainable rate: clamped to one round trip per period
    high = effective_rate_rps(PROF, serve_req(1e9), res.plan, NET)
    assert high == pytest.approx(1.0 / period, rel=1e-12)
    # below it: the requested rate stands
    assert effective_rate_rps(PROF, serve_req(1e-3), res.plan, NET) == 1e-3
    # sequential training chains are never clamped
    assert effective_rate_rps(
        PROF, serve_req(1e9, schedule="seq", M=1), res.plan, NET) == 1e9
    # the TR clamp (two-direction period) is at least as tight as the
    # forward-only clamp an IF chain with the same shape would get
    if_req = serve_req(1e9, mode=IF)
    if_rate = effective_rate_rps(PROF, if_req, res.plan, NET)
    assert high <= if_rate + 1e-15
